"""Per-architecture smoke tests: reduced config, one loss + prefill + decode
step on CPU; output shapes + finiteness; prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import build_model


def make_batch(cfg, b=2, s=32, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.vision is not None:
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision.n_patches, cfg.d_model)),
            jnp.float32)
        batch["loss_mask"] = batch["loss_mask"].at[:, :cfg.vision.n_patches].set(0)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, specs = model.init(jax.random.key(0))
    # spec tree mirrors the param tree
    assert (jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, params))
            == jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, specs,
                             is_leaf=lambda s: not isinstance(s, dict))))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    # gradients flow and are finite
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_smoke_serve_roundtrip(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    b, s = 2, 32
    batch = make_batch(cfg, b, s)
    cache, _ = model.init_cache(b, s + 8)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape[0] == b
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill logits"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok,
                                                jnp.asarray(s))
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: decode logits"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2.5-3b", "hymba-1.5b",
                                  "xlstm-1.3b", "deepseek-v2-lite-16b"])
def test_decode_matches_prefill(arch):
    """prefill(s) + decode(token) must equal prefill(s+1) at the new
    position — the KV-cache / recurrent-state correctness invariant."""
    cfg = get_smoke(arch)
    if cfg.moe is not None:
        # dropping at different batch shapes legitimately changes outputs;
        # test the cache path with capacity high enough that nothing drops
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(1))
    b, s = 2, 24
    batch = make_batch(cfg, b, s, key=5)
    cache, _ = model.init_cache(b, s + 4)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step_logits, _ = jax.jit(model.decode_step)(params, cache, tok,
                                                jnp.asarray(s))

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok[:, None]], 1)
    batch2["labels"] = jnp.concatenate(
        [batch["labels"], jnp.zeros((b, 1), jnp.int32)], 1)
    batch2["loss_mask"] = jnp.ones((b, s + 1), jnp.float32)
    cache2, _ = model.init_cache(b, s + 4)
    full_logits, _ = jax.jit(model.prefill)(params, batch2, cache2)
    # xlstm's chunkwise path runs its einsums in bf16 (TPU MXU layout);
    # chunked-vs-stepwise bf16 rounding orders differ slightly
    tol = 5e-3 if arch == "xlstm-1.3b" else 2e-3
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits),
                               rtol=tol, atol=tol)


def test_vlm_splices_vision_tokens():
    cfg = get_smoke("internvl2-2b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    # changing a MASKED (vision) position's token must not change the loss
    loss1, _ = jax.jit(model.loss)(params, batch)
    batch2 = dict(batch)
    batch2["tokens"] = batch["tokens"].at[:, 0].set(7)
    loss2, _ = jax.jit(model.loss)(params, batch2)
    assert float(loss1) == float(loss2)


def test_moe_load_balance_metrics():
    cfg = get_smoke("deepseek-v2-lite-16b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert float(metrics["aux_loss"]) > 0.0
    assert 0.0 <= float(metrics["dropped_frac"]) <= 1.0


def test_sliding_window_masks_distant_context():
    """hymba SWA: with window w, logits at position p must be independent of
    tokens at positions < p - w (modulo the SSM path, which is why we test
    attention in isolation via the layers API)."""
    from repro.models import layers as L

    cfg = get_smoke("hymba-1.5b")
    st = L.AttnStatic(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                      cfg.rope_theta, cfg.qkv_bias, jnp.float32)
    p, _ = L.attn_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    w = 4
    out1, _ = L.attention(p, st, x, q_pos=jnp.arange(32), window=w)
    x2 = x.at[0, 0].set(123.0)  # beyond the window of the last position
    out2, _ = L.attention(p, st, x2, q_pos=jnp.arange(32), window=w)
    np.testing.assert_allclose(out1[0, -1], out2[0, -1], rtol=1e-5)
    assert not np.allclose(out1[0, 1], out2[0, 1], rtol=1e-5)
