"""System-invariant tests: MoE routing, ring-buffer cache equivalence,
chunked-CE correctness. The hypothesis accumulator-algebra property test
lives in test_properties.py (collected only when hypothesis is
installed — the seed environment does not ship it)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import layers as L
from repro.models.common import chunked_ce_loss
from repro.models.moe import moe_apply, moe_init


# --- MoE routing invariants --------------------------------------------------

def _moe_cfg(capacity_factor=8.0):
    cfg = get_smoke("deepseek-v2-lite-16b")
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=capacity_factor))


def test_moe_identity_experts_preserve_scale():
    """With all expert FFNs zeroed, the MoE output must be exactly the
    shared-expert output (routed contribution zero)."""
    cfg = _moe_cfg()
    p, _ = moe_init(jax.random.key(0), cfg)
    p = dict(p)
    p["gate"] = jnp.zeros_like(p["gate"])
    p["up"] = jnp.zeros_like(p["up"])
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, metrics = moe_apply(p, cfg, x)
    from repro.models.layers import mlp_apply
    want = mlp_apply(p["shared"], cfg, x.reshape(-1, cfg.d_model)).reshape(
        x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


@pytest.mark.slow
def test_moe_dropless_at_high_capacity():
    cfg = _moe_cfg(capacity_factor=16.0)
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model),
                          jnp.float32)
    _, metrics = moe_apply(p, cfg, x)
    assert float(metrics["dropped_frac"]) == 0.0


@pytest.mark.slow
def test_moe_permutation_equivariance():
    """Permuting tokens within a routing group permutes outputs (dropless
    regime) — routing is position-independent."""
    cfg = _moe_cfg(capacity_factor=16.0)
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (1, 16, cfg.d_model),
                          jnp.float32)
    y, _ = moe_apply(p, cfg, x)
    perm = np.random.default_rng(0).permutation(16)
    y2, _ = moe_apply(p, cfg, x[:, perm])
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)


# --- ring-buffer sliding-window cache ---------------------------------------

def test_ring_cache_decode_matches_full_cache():
    """Decode with a ring buffer of length `window` must produce the same
    outputs as decode with a full-length cache (window masking equal)."""
    cfg = get_smoke("hymba-1.5b")
    st_ = L.AttnStatic(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.rope_theta, cfg.qkv_bias, jnp.float32)
    p, _ = L.attn_init(jax.random.key(3), cfg)
    rng = np.random.default_rng(0)
    w = cfg.sliding_window  # 16 in smoke
    s0 = 24
    x_hist = jnp.asarray(rng.standard_normal((1, s0, cfg.d_model)),
                         jnp.float32)

    # full cache: prefill s0 then decode 4 steps
    full_kv = (jnp.zeros((1, s0 + 8, cfg.n_kv_heads, cfg.head_dim)),) * 2
    _, full_kv = L.attention(p, st_, x_hist, q_pos=jnp.arange(s0),
                             window=w, cache=full_kv)
    # ring cache: same prefill
    ring_kv = (jnp.zeros((1, w, cfg.n_kv_heads, cfg.head_dim)),) * 2
    _, ring_kv = L.attention(p, st_, x_hist, q_pos=jnp.arange(s0),
                             window=w, cache=ring_kv)

    for step in range(4):
        xt = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)),
                         jnp.float32)
        pos = jnp.asarray(s0 + step)
        out_f, full_kv = L.attention(p, st_, xt, q_pos=pos[None], window=w,
                                     cache=full_kv, cache_index=pos)
        out_r, ring_kv = L.attention(p, st_, xt, q_pos=pos[None], window=w,
                                     cache=ring_kv, cache_index=pos)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-6)


# --- chunked CE --------------------------------------------------------------

def test_chunked_ce_matches_direct():
    cfg = get_smoke("olmo-1b").replace(loss_chunk=8)
    rng = np.random.default_rng(1)
    b, s, d, v = 2, 24, cfg.d_model, cfg.padded_vocab
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)) * 0.02, jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    mask = jnp.asarray(rng.random((b, s)) > 0.3, jnp.float32)

    sum_loss, cnt = chunked_ce_loss(x, w, labels, mask, cfg)

    logits = (x @ w).astype(jnp.float32)
    logits = logits + jnp.where(jnp.arange(v) < cfg.vocab_size, 0.0, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.sum((lse - gold) * mask)
    assert abs(float(sum_loss) - float(want)) / abs(float(want)) < 1e-6
    assert float(cnt) == float(jnp.sum(mask))


def test_chunked_ce_padded_vocab_never_predicted():
    """The padded vocab region must be masked out of the softmax."""
    cfg = get_smoke("olmo-1b")
    assert cfg.padded_vocab > cfg.vocab_size
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    # head weight that strongly favors a padded token
    w = jnp.zeros((cfg.d_model, cfg.padded_vocab))
    w = w.at[:, cfg.vocab_size + 3].set(100.0)
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.ones((1, 4), jnp.float32)
    sum_loss, _ = chunked_ce_loss(x, w, labels, mask, cfg)
    # if the padded logit leaked, lse would be ~100*|x| and loss enormous
    assert float(sum_loss) / 4 < 50.0


