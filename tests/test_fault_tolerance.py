"""Fault-tolerance: crash/restart with auto-resume must reproduce the
unfailed loss trajectory exactly (deterministic data + checkpointed state)."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.ft import FailureInjector, SimulatedFailure, Watchdog, run_with_restarts
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def _mk(ckpt_dir, failure_hook=None, steps=12):
    cfg = get_smoke("olmo-1b").replace(loss_chunk=32)
    tc = TrainConfig(steps=steps, microbatches=1, log_every=1, ckpt_every=4,
                     warmup=2, ckpt_dir=ckpt_dir,
                     opt=AdamWConfig(lr=1e-3, weight_decay=0.0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    return Trainer(cfg, tc, data, failure_hook=failure_hook)


@pytest.mark.slow
def test_crash_restart_resumes_exact_trajectory(tmp_path):
    # reference run, no failures
    ref = _mk(str(tmp_path / "ref"))
    ref.run()
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_history}

    # failing run: crashes at steps 5 and 9, restarts from checkpoints
    injector = FailureInjector(fail_at=[5, 9])
    trainer, restarts = run_with_restarts(
        lambda: _mk(str(tmp_path / "ft"), failure_hook=injector),
        max_restarts=3)
    assert restarts == 2
    ft_losses = {m["step"]: m["loss"] for m in trainer.metrics_history}
    # the final step's loss must match the reference bit-for-bit: same data,
    # same state (checkpoint at step 4 and 8, deterministic replay)
    assert abs(ft_losses[12] - ref_losses[12]) < 1e-6


@pytest.mark.slow
def test_resume_skips_completed_steps(tmp_path):
    t1 = _mk(str(tmp_path), steps=8)
    t1.run()
    # a new trainer picks up at the last checkpoint, not step 0
    t2 = _mk(str(tmp_path), steps=8)
    assert t2.step == 8  # nothing left to do
    t2.run()


def test_watchdog_counts_stragglers():
    import time

    w = Watchdog(deadline_s=0.05)
    w.step_started(1)
    time.sleep(0.15)
    w.step_finished()
    assert w.straggler_events >= 1
    w.step_started(2)
    w.step_finished()  # fast step: no event
    assert w.straggler_events == 1


def test_injector_fires_once_per_step():
    inj = FailureInjector(fail_at=[3])
    inj(1)
    inj(2)
    try:
        inj(3)
        assert False, "should have raised"
    except SimulatedFailure:
        pass
    inj(3)  # second time: no raise (already consumed)
