"""Compensation-scheme registry + Policy API tests.

The acceptance bar for the registry redesign:

* every REGISTERED scheme's Pallas kernel matches its registered oracle
  bitwise on the single, batched, and sharded-merge paths (the callables
  are shared, so this pins the plumbing, not luck);
* the accuracy ladder on GenDot data orders naive >= kahan >= dot2, with
  dot2 beating kahan by >= 2 decimal digits at cond 1e10;
* registering a toy scheme makes it usable through ops.dot / ops.asum /
  batched_* / sharded_* and visible to core/ecm.py predictions with no
  edits outside the registration call;
* the legacy ``mode=`` alias is GONE: passing it is a TypeError;
* unknown scheme names fail fast at the API boundary with the registered
  menu in the message.
"""

import dataclasses
import functools
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecm, kahan as K, numerics
from repro.distributed import collectives as coll
from repro.kernels import ops, ref, schemes
from repro.kernels.engine import CompensatedReduction, merge_accumulators
from repro.kernels.schemes import (
    CompensationScheme,
    InstructionMix,
    Policy,
    use_policy,
)

# ragged (pad-requiring) size, 3 sequential steps at unroll=1; the
# pairwise cascade's fold branch needs > PAIRWISE_FOLD steps and gets its
# own dedicated test below (interpret-mode grids cost wall time per step,
# so the registry-wide sweeps stay small).
N_BITWISE = 8 * 128 * 3 + 41


def _data(n, seed=0):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.standard_normal(n), jnp.float32),
            jnp.asarray(r.standard_normal(n), jnp.float32))


# --- every registered scheme: kernel == oracle, bitwise ---------------------

@pytest.mark.parametrize("name", schemes.names())
def test_registered_scheme_kernel_matches_oracle_bitwise(name):
    a, b = _data(N_BITWISE, seed=1)
    got = ops.dot(a, b, scheme=name, unroll=1)
    want = ref.dot_ref(a, b, scheme=name, rows=8)
    assert float(got) == float(want), f"dot[{name}] not bitwise"
    gs = ops.asum(a, scheme=name, unroll=1)
    ws = ref.sum_ref(a, scheme=name, rows=8)
    assert float(gs) == float(ws), f"asum[{name}] not bitwise"


@pytest.mark.parametrize("name", schemes.names())
def test_registered_scheme_batched_bitwise(name):
    a, b = _data(3 * N_BITWISE, seed=2)
    a = a.reshape(3, N_BITWISE)
    b = b.reshape(3, N_BITWISE)
    got = ops.batched_dot(a, b, scheme=name, unroll=1)
    want = jnp.stack([ops.dot(a[i], b[i], scheme=name, unroll=1)
                      for i in range(3)])
    assert np.array_equal(np.asarray(got), np.asarray(want)), name
    gs = ops.batched_asum(a, scheme=name, unroll=1)
    ws = jnp.stack([ops.asum(a[i], scheme=name, unroll=1) for i in range(3)])
    assert np.array_equal(np.asarray(gs), np.asarray(ws)), name


@pytest.mark.parametrize("name", schemes.names())
def test_registered_scheme_sharded_merge_bitwise(name):
    """Function-level sharded path: the gather-side fold of per-shard
    (s, c) grids equals the single-device two-sum tree on the stacked
    grids for every scheme (the shard_map wrapper adds no arithmetic —
    the full-mesh run is pinned by the slow-tier engine tests)."""
    eng = CompensatedReduction(scheme=name, unroll=1)
    x, _ = _data(4 * 8 * 128 * 2, seed=3)
    shards = x.reshape(4, -1)
    accs = [eng.sum_accumulators(shards[i]) for i in range(4)]
    ss = jnp.stack([a.s for a in accs])
    cs = jnp.stack([a.c for a in accs])
    got = coll.merge_sharded_accumulators(ss, cs)
    want = merge_accumulators(ss, cs)
    assert float(got) == float(want), name


def test_pairwise_fold_path_bitwise():
    """steps > PAIRWISE_FOLD so the cascade's fold branch actually fires
    in both the kernel and the oracle — bitwise, and c must be engaged."""
    n = 8 * 128 * (schemes.PAIRWISE_FOLD + 3) + 41
    a, b = _data(n, seed=4)
    got = ops.dot(a, b, scheme="pairwise", unroll=1)
    want = ref.dot_ref(a, b, scheme="pairwise", rows=8)
    assert float(got) == float(want)
    eng = CompensatedReduction(scheme="pairwise", unroll=1)
    acc = eng.sum_accumulators(a)
    assert np.abs(np.asarray(acc.c)).max() > 0  # the cascade level filled


# --- accuracy ladder on GenDot data -----------------------------------------

#: requested GenDot condition numbers (the achieved cond is ~n/2 larger;
#: printed by bench_accuracy). fp32 product rounding saturates any
#: product-rounding scheme past achieved cond ~ 1/eps ~ 1.7e7.
LADDER_CONDS = (1e4, 1e6, 1e8, 1e10, 1e12)
SATURATION_COND = 1.0 / schemes.EPS32


@functools.lru_cache(maxsize=None)
def _ladder_errors(cond, n=8192):
    a, b, exact, achieved = numerics.gen_dot(n, cond, seed=int(np.log10(cond)))
    errs = {
        name: numerics.relative_error(
            float(ops.dot(jnp.asarray(a), jnp.asarray(b), scheme=name,
                          unroll=1)), exact)
        for name in ("naive", "kahan", "pairwise", "dot2")}
    return errs, achieved


@pytest.mark.parametrize("cond", LADDER_CONDS)
def test_accuracy_ladder(cond):
    errs, achieved = _ladder_errors(cond)
    # dot2 (TwoProd kills the product floor) sits >= 2 decimal digits
    # below BOTH product-rounding schemes at every condition number.
    assert errs["dot2"] <= 1e-2 * errs["kahan"], (errs, achieved)
    assert errs["dot2"] <= 1e-2 * errs["naive"], (errs, achieved)
    if achieved < SATURATION_COND:
        # meaningful regime: compensation strictly helps, the cascade
        # never hurts.
        assert errs["kahan"] <= errs["naive"], (errs, achieved)
        assert errs["pairwise"] <= errs["naive"] * 1.01, (errs, achieved)
    else:
        # past saturation naive/kahan are both product-rounding noise of
        # the same magnitude; only the scale may be compared.
        assert errs["kahan"] <= errs["naive"] * 3.0, (errs, achieved)


def test_dot2_beats_kahan_by_2_digits_at_cond_1e10():
    errs, achieved = _ladder_errors(1e10)
    assert errs["kahan"] / max(errs["dot2"], 1e-30) >= 100.0, (errs, achieved)


@pytest.mark.parametrize("name", schemes.names())
def test_apriori_error_bound_holds(name):
    errs, achieved = _ladder_errors(1e4)
    bound = schemes.get(name).error_bound(8192, achieved)
    assert np.isfinite(bound) and bound > 0
    assert errs[name] <= bound, (name, errs[name], bound)


# --- toy-scheme registration: one call, every entry point -------------------

def _toy_scheme():
    """TwoSum accumulation with a plainly-rounded product — distinct from
    every built-in (kahan uses the 4-add step, dot2 adds TwoProd)."""
    def update(s, c, x, step):
        del step
        s, e = K.two_sum(s, x)
        return s, c + e

    return CompensationScheme(
        name="toy-sum2", update=update,
        instruction_mix=InstructionMix(adds=7, muls=1),
        error_bound=lambda n, cond, eps=schemes.EPS32: (eps + n * eps * eps)
        * cond,
        description="test-only: Sum2 accumulation of rounded products")


def test_toy_scheme_reaches_every_entry_point():
    toy = schemes.register(_toy_scheme())
    try:
        a, b = _data(8 * 128 * 2 + 17, seed=5)
        ab = jnp.stack([a, a]), jnp.stack([b, b])
        # ops + batched, kernel vs oracle bitwise — no edits anywhere
        got = ops.dot(a, b, scheme="toy-sum2", unroll=1)
        assert float(got) == float(ref.dot_ref(a, b, scheme=toy, rows=8))
        assert float(ops.asum(a, scheme="toy-sum2", unroll=1)) == float(
            ref.sum_ref(a, scheme=toy, rows=8))
        bd = ops.batched_dot(ab[0], ab[1], scheme="toy-sum2", unroll=1)
        assert float(bd[0]) == float(bd[1]) == float(got)
        ba = ops.batched_asum(ab[0], scheme="toy-sum2", unroll=1)
        assert np.asarray(ba).shape == (2,)
        # sharded merge path (full shard_map run: slow tier below)
        eng = CompensatedReduction(scheme="toy-sum2", unroll=1)
        acc = eng.sum_accumulators(a)
        stacked_s = jnp.stack([acc.s, acc.s])
        stacked_c = jnp.stack([acc.c, acc.c])
        merged = coll.merge_sharded_accumulators(stacked_s, stacked_c)
        assert float(merged) == float(merge_accumulators(stacked_s,
                                                         stacked_c))
        # matmul path
        m = jnp.asarray(np.random.default_rng(6).standard_normal((16, 256)),
                        jnp.float32)
        mm = ops.matmul(m, m.T, block_m=16, block_n=128, block_k=128,
                        scheme="toy-sum2")
        wm = ref.matmul_ref(m, m.T, bk=128, scheme=toy)
        # within-tile jnp.dot may reassociate differently between the
        # pallas-interpret and scan paths (see test_kernels) — tight, not
        # bitwise
        scale = np.abs(np.asarray(wm)).max()
        assert np.abs(np.asarray(mm) - np.asarray(wm)).max() / scale < 2e-6
        # ECM visibility: predictions derive from the registered mix
        blk = ecm.tpu_block_for_scheme("toy-sum2")
        assert blk.flops_per_elem == 8
        assert "toy-sum2" in ecm.registry_tpu_blocks()
        assert "toy-sum2" in ecm.registry_dot_kernels()
        r = ecm.ecm_tpu_for_scheme(ecm.TPU_V5E, "toy-sum2")
        assert r.kernel == "toy-sum2" and r.t_comp_cy > 0
    finally:
        schemes.unregister("toy-sum2")
    with pytest.raises(ValueError):
        ops.dot(a, b, scheme="toy-sum2")  # gone after unregister


@pytest.mark.slow
def test_toy_scheme_through_sharded_entry_point():
    toy = _toy_scheme()
    schemes.register(toy)
    try:
        mesh = jax.make_mesh((1,), ("data",))
        x, _ = _data(8 * 128 * 2 * 3 + 13, seed=7)
        got = coll.sharded_asum(mesh, x, scheme="toy-sum2", unroll=2)
        want = CompensatedReduction(scheme=toy, unroll=2).asum(x)
        assert float(got) == float(want)
    finally:
        schemes.unregister("toy-sum2")


# --- legacy mode= alias: REMOVED --------------------------------------------

def test_mode_alias_is_gone():
    """The deprecated alias was removed after the CI gate kept repro.*
    internals clean — passing it must now fail loudly, not silently
    resolve. (The migration note lives in repro.kernels.schemes.)"""
    a, b = _data(1024, seed=11)
    for call in (lambda: ops.dot(a, b, mode="kahan"),
                 lambda: ops.asum(a, mode="kahan"),
                 lambda: CompensatedReduction(mode="kahan"),
                 lambda: ref.dot_ref(a, b, mode="kahan"),
                 lambda: coll.sharded_asum(
                     jax.make_mesh((1,), ("data",)), a, mode="kahan")):
        with pytest.raises(TypeError, match="mode"):
            call()
    assert not hasattr(schemes, "resolve_legacy_mode")


# --- fail-fast at the API boundary ------------------------------------------

def test_unknown_scheme_fails_fast_with_menu():
    a, b = _data(1024, seed=13)
    for call in (lambda: ops.dot(a, b, scheme="bogus"),
                 lambda: ops.asum(a, scheme="bogus"),
                 lambda: ops.batched_asum(a.reshape(2, -1), scheme="bogus"),
                 lambda: CompensatedReduction(scheme="bogus"),
                 lambda: Policy(scheme="bogus")):
        with pytest.raises(ValueError) as ei:
            call()
        msg = str(ei.value)
        assert "bogus" in msg and "kahan" in msg and "dot2" in msg, msg


# --- Policy / use_policy -----------------------------------------------------

def test_policy_resolution_and_context_default():
    a, b = _data(8 * 128 + 5, seed=17)
    base = float(ops.dot(a, b, scheme="dot2", unroll=2))
    kah = float(ops.dot(a, b, scheme="kahan", unroll=2))
    # Policy object passed directly
    pol = Policy(scheme="dot2", unroll=2)
    assert float(ops.dot(a, b, scheme=pol)) == base
    # ambient context default
    with use_policy(scheme="dot2", unroll=2):
        assert float(ops.dot(a, b)) == base
        # explicit kwargs override the ambient policy
        assert float(ops.dot(a, b, scheme="kahan", unroll=2)) == kah
        with use_policy(Policy(scheme="naive", unroll=1)):
            assert schemes.current_policy().scheme.name == "naive"
        assert schemes.current_policy().scheme.name == "dot2"
    # default restored
    assert schemes.current_policy().scheme.name == "kahan"
    assert schemes.current_policy().unroll == 8


def test_policy_is_frozen_and_validates():
    pol = Policy()
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.unroll = 4
    # unsupported accumulate dtypes fail fast and ENUMERATE the menu
    with pytest.raises(ValueError) as ei:
        Policy(compute_dtype=jnp.float16)
    msg = str(ei.value)
    assert "bfloat16" in msg and "float32" in msg and "float64" in msg, msg
    with pytest.raises(ValueError, match="int32"):
        Policy(compute_dtype=jnp.int32)
    # float64 requires x64 mode; the boundary says so instead of letting
    # jax silently truncate every array to fp32 inside a trace
    if not jax.config.jax_enable_x64:
        with pytest.raises(ValueError, match="x64"):
            Policy(compute_dtype=jnp.float64)
    with pytest.raises(ValueError, match="unroll"):
        Policy(unroll=0)


# --- exact_dot float64 path (satellite fix) ----------------------------------

def test_exact_dot_float64_two_prod_error_terms():
    """The float64 path must be correctly rounded even where the naive
    products lose bits — pinned against exact rational arithmetic (the
    pre-fix fallback appended 0.0 error terms on Python < 3.13)."""
    rng = np.random.default_rng(23)
    x = (1.0 + rng.uniform(size=64) * 2.0 ** -30).astype(np.float64)
    y = (1.0 - rng.uniform(size=64) * 2.0 ** -30).astype(np.float64)
    # cancellation: append the negated running sum so products matter
    a = np.concatenate([x, [1.0]])
    b = np.concatenate([y, [-float(np.sum(x * y))]])
    got = numerics.exact_dot(a, b)
    truth = sum((Fraction(u) * Fraction(v) for u, v in
                 zip(a.tolist(), b.tolist())), Fraction(0))
    assert got == float(truth), (got, float(truth))
    # and the error-term helper itself is exact
    for u, v in zip(x.tolist(), y.tolist()):
        err = numerics._two_prod_err64(u, v)
        assert Fraction(u * v) + Fraction(err) == Fraction(u) * Fraction(v)
