"""CompensatedReduction engine tests.

The acceptance bar for the engine: the batched (batch, steps) Pallas grid
must be BITWISE-equal to a Python loop of single kernel calls (per mode),
and the sharded (s, c) merge must equal the single-device
``merge_accumulators`` tree on identical data.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import collectives as coll
from repro.kernels import engine, ops
from repro.kernels.engine import (
    Accumulator,
    CompensatedReduction,
    merge_accumulators,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ragged (pad-requiring) size — the block-aligned case is a strict subset
# (padding becomes a no-op) and is covered by the bf16 test at 4096
SIZES = [8 * 128 * 3 + 41]


def _batch(b, n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((b, n)).astype(dtype)),
            jnp.asarray(rng.standard_normal((b, n)).astype(dtype)))


# --- batched grid == per-call loop, bitwise ---------------------------------

@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("scheme", ["naive", "kahan", "dot2"])
def test_batched_dot_bitwise_matches_loop(n, scheme):
    a, b = _batch(5, n, seed=n)
    got = ops.batched_dot(a, b, scheme=scheme, unroll=2)
    want = jnp.stack([ops.dot(a[i], b[i], scheme=scheme, unroll=2)
                      for i in range(a.shape[0])])
    assert np.array_equal(np.asarray(got), np.asarray(want)), scheme


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("scheme", ["naive", "kahan"])
def test_batched_asum_bitwise_matches_loop(n, scheme):
    x, _ = _batch(4, n, seed=n + 7)
    got = ops.batched_asum(x, scheme=scheme, unroll=2)
    want = jnp.stack([ops.asum(x[i], scheme=scheme, unroll=2)
                      for i in range(x.shape[0])])
    assert np.array_equal(np.asarray(got), np.asarray(want)), scheme


def test_batched_bf16_promotion_bitwise():
    """Promotion to the engine's COMPUTE_DTYPE happens once, before
    padding; batched and per-call paths promote identically."""
    a, b = _batch(3, 4096, seed=3)
    a16, b16 = a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    got = ops.batched_dot(a16, b16, scheme="kahan", unroll=2)
    assert got.dtype == engine.COMPUTE_DTYPE
    want = jnp.stack([ops.dot(a16[i], b16[i], scheme="kahan", unroll=2)
                      for i in range(3)])
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_vmap_dispatches_to_batched_grid():
    """jax.vmap of the scalar entry points must produce the batched-grid
    result (custom_vmap rule), bitwise-equal to the per-call loop."""
    a, b = _batch(4, 8 * 128 * 2 + 9, seed=11)
    vd = jax.vmap(lambda x, y: ops.dot(x, y, scheme="kahan", unroll=2))(a, b)
    ld = jnp.stack([ops.dot(a[i], b[i], scheme="kahan", unroll=2)
                    for i in range(4)])
    assert np.array_equal(np.asarray(vd), np.asarray(ld))
    vs = jax.vmap(lambda x: ops.asum(x, scheme="kahan", unroll=2))(a)
    ls = jnp.stack([ops.asum(a[i], scheme="kahan", unroll=2) for i in range(4)])
    assert np.array_equal(np.asarray(vs), np.asarray(ls))


# --- accumulator pytree ------------------------------------------------------

def test_accumulator_pytree_and_combine():
    eng = CompensatedReduction(scheme="kahan", unroll=1)
    a, b = _batch(1, 4096, seed=5)
    acc1 = eng.dot_accumulators(a[0, :2048], b[0, :2048])
    acc2 = eng.dot_accumulators(a[0, 2048:], b[0, 2048:])
    assert isinstance(acc1, Accumulator)
    leaves = jax.tree.leaves(acc1)
    assert len(leaves) == 2  # (s, c) — first-class pytree
    merged = acc1.combine(acc2)
    # merged total approximates the full dot at fp32 fidelity
    full = float(eng.dot(a[0], b[0]))
    assert abs(float(merged.total()) - full) <= 1e-5 * max(abs(full), 1.0)


def test_accumulator_total_batched_is_vmap_of_tree():
    eng = CompensatedReduction(scheme="kahan", unroll=2)
    x, _ = _batch(3, 8 * 128 * 4, seed=9)
    acc = eng.batched_sum_accumulators(x)
    got = acc.total()
    want = jax.vmap(merge_accumulators)(acc.s, acc.c)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# --- interpret=None resolution ----------------------------------------------

def test_interpret_default_resolves_identically(monkeypatch):
    """interpret=None must resolve through the single engine authority for
    all three reductions (no per-wrapper re-implementation)."""
    calls = []
    real = engine.resolve_interpret

    def spy(v):
        calls.append(v)
        return real(v)

    monkeypatch.setattr(engine, "resolve_interpret", spy)
    a, b = _batch(1, 2048, seed=13)
    m = jnp.ones((16, 128), jnp.float32)
    ops.dot(a[0], b[0], interpret=None)
    ops.asum(a[0], interpret=None)
    ops.matmul(m, m.T, block_m=16, block_n=128, block_k=128, interpret=None)
    assert len(calls) >= 3 and all(v is None for v in calls)
    # and the resolved value is the documented policy
    assert real(None) == (jax.default_backend() != "tpu")
    assert real(True) is True and real(False) is False


def test_interpret_none_matches_explicit_on_cpu():
    a, b = _batch(1, 2048, seed=17)
    expect = jax.default_backend() != "tpu"
    for fn in (lambda i: ops.dot(a[0], b[0], interpret=i),
               lambda i: ops.asum(a[0], interpret=i)):
        assert float(fn(None)) == float(fn(expect))


# --- sharded merge vs single-device tree ------------------------------------

def test_merge_sharded_equals_single_device_tree():
    """Function-level contract: the gather-side fold IS the single-device
    two-sum tree on the stacked per-device grids."""
    eng = CompensatedReduction(scheme="kahan", unroll=2)
    x, _ = _batch(4, 8 * 128 * 2 * 3, seed=21)
    accs = [eng.sum_accumulators(x[i]) for i in range(4)]
    ss = jnp.stack([a.s for a in accs])
    cs = jnp.stack([a.c for a in accs])
    got = coll.merge_sharded_accumulators(ss, cs)
    want = merge_accumulators(ss, cs)
    assert float(got) == float(want)


@pytest.mark.slow  # subsumed by the 2-device subprocess test below
def test_sharded_asum_single_device_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    x, _ = _batch(1, 8 * 128 * 4 + 13, seed=23)
    got = coll.sharded_asum(mesh, x[0], scheme="kahan", unroll=2)
    want = CompensatedReduction(scheme="kahan", unroll=2).asum(x[0])
    assert float(got) == float(want)


@pytest.mark.slow  # subsumed by the 2-device subprocess test below
def test_sharded_dot_single_device_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    a, b = _batch(1, 5000, seed=29)
    got = coll.sharded_dot(mesh, a[0], b[0], unroll=2)
    want = CompensatedReduction(unroll=2).dot(a[0], b[0])
    assert float(got) == float(want)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed import collectives as coll
    from repro.kernels.engine import CompensatedReduction, merge_accumulators

    assert len(jax.devices()) == 2
    mesh = jax.make_mesh((2,), ("data",))
    rng = np.random.default_rng(2)
    n = 2 * (8 * 128 * 2 * 3)
    x = jnp.asarray(rng.standard_normal(n) * 1e3, jnp.float32)
    got = coll.sharded_asum(mesh, x, scheme="kahan", unroll=2)

    eng = CompensatedReduction(scheme="kahan", unroll=2)
    shards = x.reshape(2, n // 2)
    accs = [eng.sum_accumulators(shards[i]) for i in range(2)]
    ss = jnp.stack([a.s for a in accs])
    cs = jnp.stack([a.c for a in accs])
    want = merge_accumulators(ss, cs)
    assert float(got) == float(want), (float(got), float(want))
    print("OK")
""")


def test_sharded_merge_matches_single_device_on_2_devices():
    """The real cross-device check: 2 forced host devices in a subprocess
    (the device-count flag must not leak into this process). The gathered
    (s, c) grids fold to the same bits as the single-device tree; wider
    merges of stacked grids are covered at function level above."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    res = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


# --- matmul on the engine contract -------------------------------------------

def _mm_batch(b, m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((b, m, k)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, k, n)), jnp.float32))

_MM_BLOCKS = dict(block_m=16, block_n=128, block_k=256)


def test_batched_matmul_bitwise_matches_loop_every_scheme():
    """Acceptance bar: ops.batched_matmul is bitwise-equal to a Python
    loop of ops.matmul calls for EVERY registered scheme (ragged shapes —
    the engine pads/clamps identically on both paths)."""
    from repro.kernels import schemes

    a, b = _mm_batch(3, 24, 700, 130, seed=31)
    for name in schemes.names():
        got = ops.batched_matmul(a, b, scheme=name, **_MM_BLOCKS)
        want = jnp.stack([ops.matmul(a[i], b[i], scheme=name, **_MM_BLOCKS)
                          for i in range(3)])
        assert np.array_equal(np.asarray(got), np.asarray(want)), name


def test_vmap_matmul_dispatches_to_batched_grid():
    a, b = _mm_batch(3, 24, 700, 130, seed=37)
    vm = jax.vmap(lambda x, y: ops.matmul(x, y, scheme="kahan",
                                          **_MM_BLOCKS))(a, b)
    lp = jnp.stack([ops.matmul(a[i], b[i], scheme="kahan", **_MM_BLOCKS)
                    for i in range(3)])
    assert np.array_equal(np.asarray(vm), np.asarray(lp))


def test_matmul_grad_flows_through_engine():
    """ops.matmul is differentiable (custom VJP): the backward matmuls
    run the same compensated kernel, and the result matches the plain
    fp32 matmul cotangents tightly."""
    a, b = _mm_batch(1, 16, 512, 128, seed=41)
    a, b = a[0], b[0]

    def loss(x, y):
        return jnp.sum(ops.matmul(x, y, scheme="kahan", **_MM_BLOCKS))

    da, db = jax.grad(loss, argnums=(0, 1))(a, b)
    da_ref = jnp.ones((16, 128)) @ b.T
    db_ref = a.T @ jnp.ones((16, 128))
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref),
                               rtol=1e-5, atol=1e-4)


def test_matmul_accumulators_are_engine_accumulators():
    """The matmul kernel emits raw (s, c) grids under the shared
    total = finalize(s, c) contract; the collapsed entry point equals
    finalize-then-slice of the producer's output."""
    a, b = _mm_batch(1, 24, 700, 130, seed=43)
    a, b = a[0], b[0]
    eng = CompensatedReduction(scheme="dot2", blocks=(16, 128, 256))
    acc = eng.matmul_accumulators(a, b)
    assert isinstance(acc, Accumulator)
    want = eng.scheme.finalize(acc.s, acc.c)[:24, :130]
    got = eng.matmul(a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_sharded_matmul_single_device_matches_merge():
    """Gather-side contract: sharded_matmul == merge_accumulator_grids of
    the stacked per-device (s, c) grids (1-device mesh; the 2-device
    run is pinned by the slow-tier subprocess test below)."""
    from repro.kernels.engine import merge_accumulator_grids

    mesh = jax.make_mesh((1,), ("data",))
    a, b = _mm_batch(1, 24, 512, 130, seed=47)
    a, b = a[0], b[0]
    got = coll.sharded_matmul(mesh, a, b, scheme="kahan", **_MM_BLOCKS)
    eng = CompensatedReduction(scheme="kahan", blocks=(16, 128, 256))
    acc = eng.matmul_accumulators(a, b)
    want = merge_accumulator_grids(acc.s[None], acc.c[None])[:24, :130]
    assert np.array_equal(np.asarray(got), np.asarray(want))


_MULTIDEV_MATMUL_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed import collectives as coll
    from repro.kernels.engine import (CompensatedReduction,
                                      merge_accumulator_grids)

    assert len(jax.devices()) == 2
    mesh = jax.make_mesh((2,), ("data",))
    rng = np.random.default_rng(5)
    m, k, n = 24, 1024, 130
    a = jnp.asarray(rng.standard_normal((m, k)) * 1e2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)) * 1e2, jnp.float32)
    got = coll.sharded_matmul(mesh, a, b, scheme="kahan", block_m=16,
                              block_n=128, block_k=256)

    eng = CompensatedReduction(scheme="kahan", blocks=(16, 128, 256))
    accs = [eng.matmul_accumulators(a[:, i*(k//2):(i+1)*(k//2)],
                                    b[i*(k//2):(i+1)*(k//2)])
            for i in range(2)]
    ss = jnp.stack([acc.s for acc in accs])
    cs = jnp.stack([acc.c for acc in accs])
    want = merge_accumulator_grids(ss, cs)[:m, :n]
    assert np.array_equal(np.asarray(got), np.asarray(want))
    print("OK")
""")


@pytest.mark.slow
def test_sharded_matmul_matches_device_major_merge_on_2_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    res = subprocess.run([sys.executable, "-c", _MULTIDEV_MATMUL_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
