"""Hypothesis property tests (EFT invariants, accumulator algebra).

Collected ONLY when ``hypothesis`` is installed — the seed container does
not ship it, and an unconditional import used to kill tier-1 collection
for the whole suite. Everything deterministic stays in test_kahan_core.py
/ test_invariants.py; this module is the optional property-based layer.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import kahan as K  # noqa: E402
from repro.core.kahan import KahanAccumulator  # noqa: E402

f32 = st.floats(min_value=-float(2 ** 40), max_value=float(2 ** 40),
                allow_nan=False, allow_infinity=False, allow_subnormal=False,
                width=32)


@given(f32, f32)
@settings(max_examples=200, deadline=None)
def test_two_sum_exact(a, b):
    """two_sum is an error-free transformation: a + b == s + e EXACTLY
    (verified in exact rational arithmetic via Fraction). fp32 here — JAX
    x64 is off and the property is precision-independent."""
    from fractions import Fraction

    a = float(np.float32(a))
    b = float(np.float32(b))
    s, e = K.two_sum(jnp.float32(a), jnp.float32(b))
    s, e = float(s), float(e)
    assert Fraction(a) + Fraction(b) == Fraction(s) + Fraction(e)


@given(f32, f32)
@settings(max_examples=100, deadline=None)
def test_two_sum_matches_fast_two_sum_when_ordered(a, b):
    hi, lo = (a, b) if abs(a) >= abs(b) else (b, a)
    s1, e1 = K.two_sum(jnp.float32(hi), jnp.float32(lo))
    s2, e2 = K.fast_two_sum(jnp.float32(hi), jnp.float32(lo))
    assert float(s1) == float(s2)
    assert float(e1) == float(e2)


@given(st.floats(min_value=-float(2 ** 30), max_value=float(2 ** 30),
                 allow_nan=False, allow_subnormal=False, width=32),
       st.floats(min_value=-float(2 ** 30), max_value=float(2 ** 30),
                 allow_nan=False, allow_subnormal=False, width=32))
@settings(max_examples=200, deadline=None)
def test_two_prod_exact_fp32(a, b):
    """two_prod: a*b == p + e exactly (fp32 products are exact in fp64).

    Veltkamp splitting requires the error term not to underflow — products
    near the subnormal boundary are excluded (|a*b| > 2^-70 keeps the
    e ~ eps*|ab| term in normal range with margin)."""
    from hypothesis import assume

    assume(a == 0.0 or b == 0.0 or abs(float(a) * float(b)) > 2.0 ** -70)
    p, e = K.two_prod(jnp.float32(a), jnp.float32(b))
    assert float(np.float64(a) * np.float64(b)) == float(p) + float(e) or \
        abs((np.float64(a) * np.float64(b) - (float(p) + float(e)))
            / max(1e-30, abs(np.float64(a) * np.float64(b)))) < 1e-14


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                          allow_subnormal=False, width=32),
                min_size=2, max_size=40))
@settings(max_examples=50, deadline=None)
def test_accumulator_split_merge_consistency(xs):
    """add-all == merge(add-half, add-half) up to fp32 noise of the total."""
    half = len(xs) // 2
    a = KahanAccumulator.zeros_like(jnp.zeros(()))
    for x in xs:
        a = a.add(jnp.float32(x))
    b1 = KahanAccumulator.zeros_like(jnp.zeros(()))
    for x in xs[:half]:
        b1 = b1.add(jnp.float32(x))
    b2 = KahanAccumulator.zeros_like(jnp.zeros(()))
    for x in xs[half:]:
        b2 = b2.add(jnp.float32(x))
    merged = b1.merge(b2)
    scale = max(sum(abs(float(np.float32(x))) for x in xs), 1.0)
    assert abs(float(a.total()) - float(merged.total())) <= 1e-5 * scale
