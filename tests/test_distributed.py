"""Distribution-layer tests: sharding rule mapping (shape-aware
degradation), compression codec + error feedback, HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.distributed import sharding as shd
from repro.distributed import compression as comp
from repro.perf import hlo_analysis


# --- sharding rules ---------------------------------------------------------

def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_physical_spec_basic():
    mesh = _mesh11()
    spec = shd.physical_spec(mesh, shd.TRAIN_RULES, P("embed", "mlp"),
                             (128, 256))
    # axis size 1 -> mapping dropped (replicated is equivalent)
    assert spec == P()


def test_physical_spec_divisibility_degrades():
    mesh = jax.make_mesh((1,), ("model",))
    rules = shd.Rules("t", {"heads": "model"})
    # heads=5 on 1-way axis -> fine but size 1 -> dropped
    assert shd.physical_spec(mesh, rules, P("heads"), (5,)) == P()


def test_physical_spec_absent_axis_dropped():
    mesh = _mesh11()  # no "pod" axis
    spec = shd.physical_spec(mesh, shd.TRAIN_RULES, P("batch", None),
                             (8, 16))
    assert spec == P()  # ("pod","data") -> ("data",) -> size 1 -> dropped


def test_physical_spec_no_axis_reuse():
    import types

    rules = shd.Rules("t", {"a": "model", "b": "model"})
    mesh = types.SimpleNamespace(shape={"model": 2})  # duck-typed 2-way mesh

    spec = shd.physical_spec(mesh, rules, P("a", "b"), (4, 4))
    # second use of "model" must be dropped
    assert spec in (P("model"), P("model", None))


def test_physical_spec_divisibility_with_real_axis():
    import types

    mesh = types.SimpleNamespace(shape={"model": 16})
    rules = shd.Rules("t", {"heads": "model", "kv_seq": "model"})
    # 25 heads don't divide 16 -> replicated
    assert shd.physical_spec(mesh, rules, P("heads"), (25,)) == P()
    # 32768 kv positions do
    assert shd.physical_spec(mesh, rules, P(None, "kv_seq"),
                             (4, 32768)) == P(None, "model")


def test_constrain_is_noop_outside_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x


# --- compression ------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    scale = jnp.max(jnp.abs(g))
    q = comp.quantize(g, scale)
    deq = comp.dequantize(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) / 127.0 + 1e-6


def test_error_feedback_accumulates_residual():
    rng = np.random.default_rng(1)
    grads = {"w": jnp.asarray(rng.standard_normal(512), jnp.float32)}
    errors = jax.tree.map(jnp.zeros_like, grads)
    qt, errors = comp.ef_step(grads, errors)
    (q, scale) = qt["w"]
    deq = comp.dequantize(q, scale)
    np.testing.assert_allclose(np.asarray(deq + errors["w"]),
                               np.asarray(grads["w"]), rtol=0, atol=1e-6)


def test_error_feedback_converges_where_plain_quant_stalls():
    """SGD on a quadratic with tiny gradients: int8 quantization alone
    rounds small grads to zero; error feedback accumulates them."""
    target = jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)
    w_ef = jnp.zeros((64,))
    w_pq = jnp.zeros((64,))
    err = jnp.zeros((64,))
    big = jnp.zeros((64,)).at[0].set(100.0)  # one huge coordinate
    lr = 0.05
    for _ in range(400):
        g_ef = (w_ef - target) + big * 0  # plain quadratic grads
        g_pq = (w_pq - target)
        # shared scale dominated by an artificial large component
        scale = jnp.float32(50.0)
        corrected = g_ef + err
        q = comp.dequantize(comp.quantize(corrected, scale), scale)
        err = corrected - q
        w_ef = w_ef - lr * q
        w_pq = w_pq - lr * comp.dequantize(comp.quantize(g_pq, scale), scale)
    assert float(jnp.mean(jnp.abs(w_ef - target))) < 0.05
    assert float(jnp.mean(jnp.abs(w_pq - target))) > \
        float(jnp.mean(jnp.abs(w_ef - target)))


def test_compressed_psum_single_device():
    mesh = jax.make_mesh((1,), ("data",))

    @jax.jit
    def run(x):
        return compat.shard_map(
            lambda v: comp.compressed_psum(v, "data"),
            mesh=mesh, in_specs=P(), out_specs=P())(x)

    x = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
    np.testing.assert_allclose(np.asarray(run(x)), np.asarray(x), atol=0.05)


# --- HLO analyzer -----------------------------------------------------------

HLO_SAMPLE = """
HloModule test, is_scheduled=true

%body.1 (p.1: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p.1 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.1 = s32[] get-tuple-element(%p.1), index=0
  %gte.2 = f32[64,64]{1,0} get-tuple-element(%p.1), index=1
  %dot.1 = f32[64,64]{1,0} dot(%gte.2, %gte.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups=[1,4]<=[4], to_apply=%add
  ROOT %tuple.1 = (s32[], f32[64,64]{1,0}) tuple(%gte.1, %ar.1)
}

%cond.1 (p.2: (s32[], f32[64,64])) -> pred[] {
  %p.2 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.3 = s32[] get-tuple-element(%p.2), index=0
  %c.1 = s32[] constant(12)
  ROOT %lt.1 = pred[] compare(%gte.3, %c.1), direction=LT
}

ENTRY %main.1 (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[64,64]{1,0}) tuple(%c0, %a)
  %w.1 = (s32[], f32[64,64]{1,0}) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w.1), index=1
}
"""


def test_hlo_analyzer_trip_weighting():
    t = hlo_analysis.analyze_text(HLO_SAMPLE)
    # 12 iterations x dot(64x64 @ 64x64) = 12 * 2*64^3 flops
    assert t.flops == 12 * 2 * 64 ** 3
    # 12 iterations of a 16 KiB all-reduce
    assert t.coll["all-reduce"] == 12 * 64 * 64 * 4
    assert t.bytes > 0


def test_deterministic_mean_single_device():
    from repro.distributed.collectives import deterministic_mean

    mesh = jax.make_mesh((1,), ("data",))
    v = jnp.asarray([3.5], jnp.float32)
    out = deterministic_mean(mesh, v, axis="data")
    assert float(out) == 3.5
