"""Checkpoint tests: atomicity, keep-N, bf16 round-trip, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                    jnp.float32),
                   "e": jnp.asarray(rng.standard_normal((32,)),
                                    jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree, extras={"data": {"step": 7}})
    restored, step, extras = ckpt.restore(str(tmp_path), tree)
    assert step == 7 and extras["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_prunes(tmp_path):
    tree = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-write: directory without the commit marker
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"junk")
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1


def test_shape_mismatch_raises(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    bad = {"params": {"w": jnp.zeros((8, 17)), "e": tree["params"]["e"]},
           "step": tree["step"]}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


def test_elastic_reshard_on_load(tmp_path):
    """Save unsharded, restore onto an explicit NamedSharding of a local
    mesh — the elasticity path (mesh shape can differ arbitrarily between
    save and load)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {
        "params": {"w": NamedSharding(mesh, P("data", "model")),
                   "e": NamedSharding(mesh, P(None))},
        "step": NamedSharding(mesh, P()),
    }
    restored, step, _ = ckpt.restore(str(tmp_path), tree,
                                     shardings=shardings)
    assert step == 3
    assert restored["params"]["w"].sharding == shardings["params"]["w"]
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
