"""Checkpoint tests: atomicity, keep-N, bf16 round-trip, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                    jnp.float32),
                   "e": jnp.asarray(rng.standard_normal((32,)),
                                    jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree, extras={"data": {"step": 7}})
    restored, step, extras = ckpt.restore(str(tmp_path), tree)
    assert step == 7 and extras["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_prunes(tmp_path):
    tree = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-write: directory without the commit marker
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"junk")
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1


def test_shape_mismatch_raises(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    bad = {"params": {"w": jnp.zeros((8, 17)), "e": tree["params"]["e"]},
           "step": tree["step"]}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


def test_elastic_reshard_on_load(tmp_path):
    """Save unsharded, restore onto an explicit NamedSharding of a local
    mesh — the elasticity path (mesh shape can differ arbitrarily between
    save and load)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {
        "params": {"w": NamedSharding(mesh, P("data", "model")),
                   "e": NamedSharding(mesh, P(None))},
        "step": NamedSharding(mesh, P()),
    }
    restored, step, _ = ckpt.restore(str(tmp_path), tree,
                                     shardings=shardings)
    assert step == 3
    assert restored["params"]["w"].sharding == shardings["params"]["w"]
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_kahan_adamw_comp_buffers_resume_bitwise(tmp_path):
    """Resume determinism of the optimizer's (s, c) state: save -> restore
    -> one step must be BITWISE-identical to an uninterrupted run. The
    comp buffer is load-bearing for bf16 params (it carries the bits bf16
    drops); silently zeroing it on restore would pass any tolerance-based
    check while breaking long-horizon accumulation."""
    from repro.optim import AdamWConfig, apply_update
    from repro.optim import init as opt_init

    rng = np.random.default_rng(11)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.bfloat16),
              "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}
    cfg = AdamWConfig(kahan=True, lr=1e-2)
    grads = [jax.tree.map(
        lambda p, s=s: jnp.asarray(
            rng.standard_normal(p.shape) * 1e-3, p.dtype), params)
        for s in range(3)]

    # uninterrupted: three steps straight through
    p_ref, st_ref = params, opt_init(cfg, params)
    for g in grads:
        p_ref, st_ref, _ = apply_update(cfg, p_ref, g, st_ref)

    # interrupted: two steps, checkpoint, restore, third step
    p, st = params, opt_init(cfg, params)
    for g in grads[:2]:
        p, st, _ = apply_update(cfg, p, g, st)
    assert st.comp is not None
    assert max(float(jnp.abs(c).max())
               for c in jax.tree.leaves(st.comp)) > 0  # comp engaged
    ckpt.save(str(tmp_path), 2, {"params": p, "opt": st})
    restored, step, _ = ckpt.restore(str(tmp_path), {"params": p, "opt": st})
    assert step == 2
    p2, st2 = restored["params"], restored["opt"]
    # the restored (s, c) state is bit-identical...
    for a, b in zip(jax.tree.leaves((p, st)), jax.tree.leaves((p2, st2))):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # ...and so is the step taken from it
    p3, st3, _ = apply_update(cfg, p2, grads[2], st2)
    for a, b in zip(jax.tree.leaves((p_ref, st_ref)),
                    jax.tree.leaves((p3, st3))):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
